"""Table III: micro-op + data-access savings from coarse (M-V) dispatch.

Per selected layer shape: uOps at scalar-MAC granularity (prior sparse
accelerators) vs M-V granularity (SSpNNA) vs one-fused-einsum-per-tile
(this repo's MXU mapping); data accesses with/without per-pair refetch.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_scene, emit, scene_metadata

# (name, dC, dN) tile channel sizes echoing Table III's layers
LAYERS = [("L2-like", 16, 32), ("L12-like", 16, 32), ("L35-like", 8, 16)]


def run():
    t, _ = build_scene(0, 48, 16384)
    coir, nbr, order = scene_metadata(t, 48)
    idx = np.asarray(coir.indices)
    mask = np.asarray(t.mask)
    pairs = int((idx[mask] >= 0).sum())
    for name, dc, dn in LAYERS:
        total_macs = pairs * dc * dn
        uops_scalar = total_macs
        uops_mv = pairs                      # one M-V op per valid pair
        uops_saving = uops_scalar / uops_mv
        # data accesses: scalar dispatch refetches the input vector per MAC
        da_scalar = pairs * (dc + dn + dc * dn / min(dc, dn))
        da_mv = pairs * dc + pairs * dn      # vector in, vector out per pair
        emit(f"tableIII/{name}/uops_saving", 0.0,
             f"{uops_saving:.0f}x ({uops_scalar:.2e}->{uops_mv:.2e})")
        emit(f"tableIII/{name}/da_saving", 0.0,
             f"{da_scalar / da_mv:.2f}x")
