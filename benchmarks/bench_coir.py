"""§IV-A: COIR metadata compression vs per-weight-plane rulebook."""
from __future__ import annotations


from benchmarks.common import build_scene, emit, scene_metadata
from repro.core.coir import coir_size_words, rulebook_size_words


def run():
    for res in (32, 48, 64):
        t, _ = build_scene(1, res, 24576)
        coir, _, _ = scene_metadata(t, res)
        cw = int(coir_size_words(coir))
        rw = int(rulebook_size_words(coir))
        arf = float(coir.arf())
        emit(f"coir/res{res}/compression", 0.0,
             f"{rw / cw:.2f}x (ARF={arf:.1f}; coir={cw} rulebook={rw} words)")
