"""Fig 4 / Fig 19 / Table IV: SCN U-Net layer profile + modeled speedup.

Layer-wise profile of the U-Net on a synthetic scene (gather/GEMM/scatter
split, Fig 4 analogue) and the AccSS3D speedup *model*: DA-bound latency of
the baseline weight-stationary rulebook dataflow vs the SPADE-tiled COIR
dataflow, at the paper's 64 KB L1 / 1 GHz operating point. Modeled numbers
are labeled as such — wall-clock speedups of the paper's ASIC cannot be
measured here. Level metadata comes from the engine's plan builder.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_scene, emit
from repro import engine
from repro.core import spade
from repro.models.scn import UNetConfig


def run():
    res, cap = 48, 16384
    t, _ = build_scene(5, res, cap)
    cfg = UNetConfig(widths=(16, 32, 48), reps=1, resolution=res,
                     capacity=cap)
    plan = engine.build_scene_plan(t, cfg, plan_tiles=False)
    total_base = total_opt = 0.0
    for li, lvl in enumerate(plan.levels):
        idx = np.asarray(lvl.sub.coir.indices)
        mask = np.asarray(lvl.mask)
        v = max(int(mask.sum()), 1)
        c = cfg.widths[li]
        attrs = spade.extract_attributes(idx, mask)
        layer = spade.LayerSpec(f"U{li}", v, v, 27, c, c, 2)
        # baseline: weight-stationary rulebook (the SCN reference impl):
        # each of the ARF*V (in, out) pairs refetches its input row and its
        # output accumulator row once, weights once per plane
        arf = float(attrs.arf_avg[0])
        da_base = arf * v * c * 2 + c * c * 27
        best = spade.explore(layer, {"CIRF": attrs, "CORF": attrs}, 64 * 1024)
        total_base += da_base
        total_opt += best.da_elems
        emit(f"fig4/level{li}", 0.0,
             f"V={v} ARF={arf:.1f} da_base={da_base:.2e} "
             f"da_spade={best.da_elems:.2e} ({da_base / best.da_elems:.1f}x)")
    # Table IV analogue (modeled, DA-bound at 64KB L1):
    emit("tableIV/modeled_da_speedup", 0.0,
         f"{total_base / total_opt:.1f}x (DA-bound model, labeled modeled)")
