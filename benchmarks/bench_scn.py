"""Fig 4 / Fig 19 / Table IV: SCN U-Net layer profile + modeled speedup.

Layer-wise profile of the U-Net on a synthetic scene (gather/GEMM/scatter
split, Fig 4 analogue) and the AccSS3D speedup *model*: DA-bound latency of
the baseline weight-stationary rulebook dataflow vs the SPADE-tiled COIR
dataflow, at the paper's 64 KB L1 / 1 GHz operating point. Modeled numbers
are labeled as such — wall-clock speedups of the paper's ASIC cannot be
measured here.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_scene, emit
from repro.core import spade
from repro.models.scn import UNetConfig, build_unet_metadata


def run():
    res, cap = 48, 16384
    t, _ = build_scene(5, res, cap)
    cfg = UNetConfig(widths=(16, 32, 48), reps=1, resolution=res,
                     capacity=cap)
    meta = build_unet_metadata(t, cfg)
    total_base = total_opt = 0.0
    for li, lvl in enumerate(meta):
        idx = np.asarray(lvl.sub_coir.indices)
        mask = np.asarray(lvl.mask)
        v = max(int(mask.sum()), 1)
        c = cfg.widths[li]
        attrs = spade.extract_attributes(idx, mask)
        layer = spade.LayerSpec(f"U{li}", v, v, 27, c, c, 2)
        # baseline: weight-stationary rulebook (the SCN reference impl):
        # inputs+outputs refetched once per weight plane
        arf = float(attrs.arf_avg[0])
        da_base = 27 * (v * c * 2) + c * c * 27
        best = spade.explore(layer, {"CIRF": attrs, "CORF": attrs}, 64 * 1024)
        total_base += da_base
        total_opt += best.da_elems
        emit(f"fig4/level{li}", 0.0,
             f"V={v} ARF={arf:.1f} da_base={da_base:.2e} "
             f"da_spade={best.da_elems:.2e} ({da_base / best.da_elems:.1f}x)")
    # Table IV analogue (modeled, DA-bound at 64KB L1):
    emit("tableIV/modeled_da_speedup", 0.0,
         f"{total_base / total_opt:.1f}x (DA-bound model, labeled modeled)")
