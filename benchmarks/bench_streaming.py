"""Incremental stream planning vs from-scratch: per-frame host plan cost.

Measures the streaming scene engine's core claim: for a LiDAR sweep whose
consecutive frames share most of their voxels, patching the previous
frame's host plan (``engine.plan.StreamPlanState`` over
``core.host_meta.StreamMetaState``) beats rebuilding it from scratch
(``build_scene_plan_host``) by a widening margin as overlap grows.

Each sweep configuration targets one steady-state voxel-overlap regime
(0.5 .. 0.98) via the synthetic sweep generator's ego-step and churn
knobs. Per frame both paths run on the *same* canonical-layout frame and
the patched plan is asserted bitwise-equal to the from-scratch one before
any number is reported — a fast-but-wrong patch cannot publish a row.

Rows:

* ``stream_plan_<cfg>`` — steady-state (frame 0's rebuild excluded) mean
  incremental plan time per frame; ``derived`` reports the realized
  overlap, the from-scratch mean and the speedup.
* ``stream_amortize_<cfg>`` — whole-sweep view including frame 0's full
  rebuild: cumulative speedup and the frame index where the incremental
  path's cumulative cost drops below from-scratch (break-even).

Standalone CLI (what the CI smoke job runs):

    python -m benchmarks.bench_streaming --quick --json BENCH_streaming.json
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, standalone_bench_main
from repro.data.scenes import N_CLASSES, make_lidar_sweep
from repro.engine.plan import StreamPlanState, build_scene_plan_host
from repro.models.scn import UNetConfig
from repro.sparse.tensor import PAD_COORD, SparseVoxelTensor

# (name, ego step, churn) -> targeted steady-state voxel overlap regime
SWEEPS = (
    ("ovl98", 0, 0.01),
    ("ovl93", 4, 0.00),
    ("ovl85", 4, 0.04),
    ("ovl75", 4, 0.12),
    ("ovl60", 8, 0.20),
    ("ovl50", 8, 0.32),
)


def _assert_plans_equal(a, b, ctx):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"plan treedefs diverged at {ctx}"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"plan leaf {i} at {ctx}")


def _pack(coords, feats, mask, frame_rows, cap):
    act = np.flatnonzero(mask)
    pc = np.full((cap, 3), PAD_COORD, np.int32)
    pf = np.zeros_like(feats)
    pm = np.zeros(cap, bool)
    pc[frame_rows[act]] = coords[act]
    pf[frame_rows[act]] = feats[act]
    pm[frame_rows[act]] = True
    return SparseVoxelTensor(pc, pf, pm)


def _sweep_case(name, step, churn, *, res, cap, n_frames, cfg, verify):
    frames, shifts = make_lidar_sweep(17, n_frames, resolution=res,
                                      capacity=cap, step=step, churn=churn)
    state = StreamPlanState(cfg, min_overlap=0.25, stream_id=f"bench-{name}")
    inc_ms, full_ms, overlaps = [], [], []
    for fno, ((c, f, _, m), shift) in enumerate(zip(frames, shifts)):
        t = SparseVoxelTensor(c, f.astype(np.float32), m)
        _, plan, frame_rows, info = state.plan_frame(t, fno, shift)
        packed = _pack(c, f.astype(np.float32), m, frame_rows, cap)
        t0 = time.perf_counter()
        want = build_scene_plan_host(packed, cfg, spec=None,
                                     plan_tiles=False)
        full_ms.append((time.perf_counter() - t0) * 1e3)
        inc_ms.append(info["plan_ms"])
        overlaps.append(info["overlap"])
        if verify:
            _assert_plans_equal(plan, want, f"{name} frame {fno}")
    # steady state: frame 0 is a rebuild by construction
    inc = float(np.mean(inc_ms[1:]))
    full = float(np.mean(full_ms[1:]))
    ovl = float(np.mean(overlaps[1:]))
    modes = state.counts
    emit(f"stream_plan_{name}", inc * 1e3,
         f"overlap={ovl:.3f} full_us={full * 1e3:.1f} "
         f"speedup={full / inc:.2f}x patched={modes['patched']} "
         f"rebuilt={modes['rebuilt']} frames={n_frames}")
    cum_inc = np.cumsum(inc_ms)
    cum_full = np.cumsum(full_ms)
    ahead = np.flatnonzero(cum_inc < cum_full)
    breakeven = int(ahead[0]) if len(ahead) else -1
    emit(f"stream_amortize_{name}", float(cum_inc[-1]) * 1e3,
         f"cum_speedup={float(cum_full[-1] / cum_inc[-1]):.2f}x "
         f"breakeven_frame={breakeven} cum_full_us={cum_full[-1] * 1e3:.1f}")
    return ovl, full / inc


def run(quick: bool = False) -> None:
    if quick:
        res, cap, n_frames = 32, 2048, 6
    else:
        res, cap, n_frames = 64, 8192, 12
    cfg = UNetConfig(widths=(16, 32, 32), reps=1, resolution=res,
                     capacity=cap, n_classes=N_CLASSES)
    results = [
        _sweep_case(name, step, churn, res=res, cap=cap, n_frames=n_frames,
                    cfg=cfg, verify=True)
        for name, step, churn in SWEEPS
    ]
    hi = [(o, s) for o, s in results if o >= 0.85]
    if hi:
        emit("stream_speedup_hi_overlap", 0.0,
             f"min_speedup={min(s for _, s in hi):.2f}x over "
             f"{len(hi)} configs with overlap>=0.85")


def main(argv=None) -> None:
    standalone_bench_main(
        run, "bench_streaming",
        quick_help="small sweep (res=32, cap=2048, 6 frames) for CI",
        description=__doc__, argv=argv)


if __name__ == "__main__":
    main()
