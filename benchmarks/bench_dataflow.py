"""Fig 22 + Fig 24: feature ablation (SPADE/CAROM/SOAR/offline) + measured
CPU speedup of SPADE-tiled execution.

Fig 22 analogue: data accesses (model, Eqn 5) of
  baseline IS dataflow  vs  +SPADE  vs  +CAROM  vs  +SOAR ordering.
Fig 24 analogue: wall-clock of the reference XLA sparse conv vs the
SPADE-tiled gather-GEMM path on this host CPU.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import build_scene, emit, scene_metadata, time_fn
from repro import engine
from repro.core import carom, soar, spade
from repro.core.sparse_conv import init_sparse_conv


def run():
    t, _ = build_scene(4, 48, 16384)
    coir, nbr, order = scene_metadata(t, 48)
    idx = np.asarray(coir.indices)
    mask = np.asarray(t.mask)
    v = int(mask.sum())
    layer = spade.LayerSpec("ablate", v, v, 27, 32, 32, 2)

    attrs_soar = spade.extract_attributes(idx, mask, order.order)
    rast = soar.raster_order(np.asarray(t.coords), mask)
    attrs_rast = spade.extract_attributes(idx, mask, rast)

    # baseline: input-stationary, fixed tile, raster order (paper's ref pt)
    da_base, _ = spade.data_accesses(layer, attrs_rast, 256, 32, 32, "IS", "CIRF")
    # + SPADE (optimal tile/walk/flavor)
    best = spade.explore(layer, {"CIRF": attrs_rast, "CORF": attrs_rast},
                         64 * 1024)
    # + SOAR ordering (better attributes)
    best_soar = spade.explore(layer, {"CIRF": attrs_soar, "CORF": attrs_soar},
                              64 * 1024)
    # + CAROM (2-level, balance on-chip vs DRAM)
    levels = [carom.MemLevel("L2", 2 << 20, 16, 1024),
              carom.MemLevel("L1", 64 << 10, 64, 1024)]
    plans = carom.carom_search(layer, {"CIRF": attrs_soar, "CORF": attrs_soar},
                               levels)
    emit("fig22/baseline_IS_da", 0.0, f"{da_base:.3e} elems")
    emit("fig22/spade_da", 0.0,
         f"{da_base / best.da_elems:.2f}x fewer ({best.walk}/{best.flavor}"
         f"/dO={best.delta_major})")
    emit("fig22/spade+soar_da", 0.0, f"{da_base / best_soar.da_elems:.2f}x fewer")
    if plans:
        emit("fig22/carom_outer_da", 0.0,
             f"{plans[0].da_elems:.3e} elems @L2 "
             f"(inner {plans[-1].da_elems:.3e} @L1)")

    # offline-SPADE (MSA table) vs input-specific (JSA) — §V-C
    msa = spade.meta_attributes([attrs_soar])
    table = spade.build_offline_table([layer], msa, 64 * 1024)
    plan_off = spade.otf_lookup(table, layer, float(attrs_soar.arf_avg[0]))
    emit("fig22/offline_vs_jsa", 0.0,
         f"{plan_off.da_elems / best_soar.da_elems:.3f}x DA of input-specific")

    # Fig 24 analogue: measured wall time of both engine backends on the
    # same SPADE-planned conv (one ConvPlan, two `backend=` forcings)
    params = init_sparse_conv(jax.random.PRNGKey(0), 27, 4, 32)
    conv_plan = engine.conv_plan_for_layer(
        coir, order.order, best_soar.delta_major,
        int(best_soar.delta_major
            * attrs_soar.at(best_soar.delta_major, "sa_minor_alloc_rst")) + 27,
        walk=best_soar.walk)
    ref_fn = jax.jit(lambda f: engine.sparse_conv(
        f, params, conv_plan, backend="reference"))
    us_ref = time_fn(ref_fn, t.feats)
    tiled_fn = jax.jit(lambda f: engine.sparse_conv(
        f, params, conv_plan, backend="sspnna", use_kernel=False))
    us_tiled = time_fn(tiled_fn, t.feats)
    emit("fig24/ref_conv", us_ref, "engine backend=reference (XLA einsum)")
    emit("fig24/spade_tiled_conv", us_tiled,
         f"{us_ref / us_tiled:.2f}x vs ref (CPU wall; "
         f"tiles={conv_plan.dispatch.n_tiles})")
