"""Shared benchmark scaffolding: scene building + timing + CSV rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import soar
from repro.core.hashgrid import build_neighbor_table, kernel_offsets
from repro.core.sparse_conv import submanifold_coir
from repro.data.scenes import make_scene
from repro.sparse.tensor import SparseVoxelTensor

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def build_scene(seed=0, resolution=48, capacity=16384):
    coords, feats, labels, mask = make_scene(seed, resolution, capacity)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    return t, labels


def scene_metadata(t: SparseVoxelTensor, resolution: int):
    coir = submanifold_coir(t, resolution, 3)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), resolution))
    order = soar.soar_order(nbr, np.asarray(t.mask), 512)
    return coir, nbr, order
