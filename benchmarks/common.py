"""Shared benchmark scaffolding: scene building + timing + CSV rows.

Timing delegates to :func:`repro.engine.autotune.measure` — the same
warmup + median-of-k harness the profile-guided dispatcher uses — so
benchmark numbers and autotune cost-table entries are directly
comparable. The fused-kernel ``block_n`` sweep lives in
``repro.engine.autotune`` now; a deprecation shim below keeps old
imports working."""
from __future__ import annotations

import time
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core import soar
from repro.core.hashgrid import build_neighbor_table, kernel_offsets
from repro.core.sparse_conv import submanifold_coir
from repro.data.scenes import make_scene
from repro.engine.autotune import measure
from repro.sparse.tensor import SparseVoxelTensor

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters=3, warmup=1, reps=1):
    """Median us/call over ``iters * reps`` timed calls (median is robust
    to background load on shared CI hosts). Thin wrapper over
    ``engine.autotune.measure`` so benches and the autotuner share one
    timing harness."""
    k = max(int(iters) * int(reps), 1)
    return measure(fn, *args, warmup=warmup, k=k).median_us


def build_scene(seed=0, resolution=48, capacity=16384):
    coords, feats, labels, mask = make_scene(seed, resolution, capacity)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    return t, labels


def scene_metadata(t: SparseVoxelTensor, resolution: int):
    coir = submanifold_coir(t, resolution, 3)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), resolution))
    order = soar.soar_order(nbr, np.asarray(t.mask), 512)
    return coir, nbr, order


# -- standalone bench CLIs ---------------------------------------------------

def standalone_bench_main(run, module_name: str, quick_help: str,
                          description: str | None = None, argv=None,
                          configure=None, run_kw=None) -> None:
    """Shared ``main()`` for benches with their own CI smoke CLI
    (``--quick`` / ``--json``): one place owns the CSV header, timing and
    the ``bench-rows/v1`` JSON artifact schema.

    ``configure(parser)`` lets a bench register extra CLI flags;
    ``run_kw(args) -> dict`` maps the parsed namespace to extra keyword
    arguments for ``run`` (e.g. ``--seed-from`` in ``bench_dispatch``).
    """
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--quick", action="store_true", help=quick_help)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact (CI perf log)")
    if configure is not None:
        configure(ap)
    args = ap.parse_args(argv)
    extra = run_kw(args) if run_kw is not None else {}
    print("name,us_per_call,derived")
    t0 = time.time()
    run(quick=args.quick, **extra)
    total_s = time.time() - t0
    print(f"# total {total_s:.1f}s", file=sys.stderr)
    if args.json:
        payload = {
            "schema": "bench-rows/v1",
            "unix_time": int(t0),
            "total_seconds": round(total_s, 2),
            "modules": [module_name],
            "rows": [{"name": n, "us_per_call": u, "derived": d}
                     for n, u, d in ROWS],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload['rows'])} rows to {args.json}",
              file=sys.stderr)


# -- fused-kernel block_n autotune (moved) -----------------------------------

def autotune_block_n(*args, **kw):
    """Deprecated shim: the ``block_n`` sweep moved into the engine."""
    warnings.warn(
        "benchmarks.common.autotune_block_n is deprecated; use "
        "repro.engine.autotune.autotune_block_n",
        DeprecationWarning, stacklevel=2)
    from repro.engine.autotune import autotune_block_n as impl
    return impl(*args, **kw)
