"""Shared benchmark scaffolding: scene building + timing + CSV rows +
the fused-kernel ``block_n`` sweep (pinned into plan specs)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import soar
from repro.core.hashgrid import build_neighbor_table, kernel_offsets
from repro.core.sparse_conv import submanifold_coir
from repro.data.scenes import make_scene
from repro.sparse.tensor import SparseVoxelTensor

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters=3, warmup=1, reps=1):
    """Mean us/call over ``iters``; with ``reps > 1``, best-of-``reps`` means
    (min is robust to background load on shared CI hosts)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
            jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)  # us
    return best


def build_scene(seed=0, resolution=48, capacity=16384):
    coords, feats, labels, mask = make_scene(seed, resolution, capacity)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    return t, labels


def scene_metadata(t: SparseVoxelTensor, resolution: int):
    coir = submanifold_coir(t, resolution, 3)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), resolution))
    order = soar.soar_order(nbr, np.asarray(t.mask), 512)
    return coir, nbr, order


# -- standalone bench CLIs ---------------------------------------------------

def standalone_bench_main(run, module_name: str, quick_help: str,
                          description: str | None = None, argv=None) -> None:
    """Shared ``main()`` for benches with their own CI smoke CLI
    (``--quick`` / ``--json``): one place owns the CSV header, timing and
    the ``bench-rows/v1`` JSON artifact schema."""
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--quick", action="store_true", help=quick_help)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact (CI perf log)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    t0 = time.time()
    run(quick=args.quick)
    total_s = time.time() - t0
    print(f"# total {total_s:.1f}s", file=sys.stderr)
    if args.json:
        payload = {
            "schema": "bench-rows/v1",
            "unix_time": int(t0),
            "total_seconds": round(total_s, 2),
            "modules": [module_name],
            "rows": [{"name": n, "us_per_call": u, "derived": d}
                     for n, u, d in ROWS],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload['rows'])} rows to {args.json}",
              file=sys.stderr)


# -- fused-kernel block_n autotune -------------------------------------------

# per-parameter-set memo so a plan-spec build sweeps each layer shape once
_BLOCK_N_CACHE: dict[tuple, int] = {}


def _block_n_candidates(n: int) -> list[int]:
    """Divisors of ``n`` worth sweeping: full-N down to 8-wide blocks."""
    cands = [b for b in (n, n // 2, n // 4) if b >= 8 and n % b == 0]
    return cands or [n]


def autotune_block_n(c_in: int, n_out: int, delta_o: int, delta_i: int,
                     *, kernel_volume: int = 27, n_tiles: int = 8,
                     iters: int = 3, seed: int = 0) -> int:
    """Pick the fused kernel's N-block for one ``(C, N, dO, dI)`` signature.

    Times ``kernels.sspnna.sspnna_fused`` on synthetic tiles at the layer's
    shape for each candidate divisor of ``n_out`` and returns the fastest.
    Memoized per full parameter set; pass as
    ``build_plan_spec(tune_block_n=...)`` so SPADE plans pin the choice in
    ``Dispatch.block_n`` instead of defaulting to full-N.
    """
    key = (c_in, n_out, delta_o, delta_i, kernel_volume, n_tiles, iters, seed)
    if key in _BLOCK_N_CACHE:
        return _BLOCK_N_CACHE[key]
    from repro.kernels.sspnna.sspnna import sspnna_fused

    rng = np.random.default_rng(seed)
    # big enough for the working sets AND the n_tiles*delta_o disjoint
    # output rows drawn below
    v = max(4 * delta_i, n_tiles * delta_o, 256)
    feats = jnp.asarray(rng.normal(size=(v, c_in)), jnp.float32)
    weights = jnp.asarray(
        rng.normal(size=(kernel_volume, c_in, n_out)) * 0.1, jnp.float32)
    in_rows = jnp.asarray(
        rng.integers(0, v, (n_tiles, delta_i)).astype(np.int32))
    out_rows = jnp.asarray(
        rng.permutation(v)[: n_tiles * delta_o]
        .reshape(n_tiles, delta_o).astype(np.int32))
    local_idx = jnp.asarray(
        rng.integers(-1, delta_i, (n_tiles, delta_o, kernel_volume))
        .astype(np.int32))
    counts = jnp.ones((n_tiles,), jnp.int32)

    best_bn, best_us = 0, float("inf")
    for bn in _block_n_candidates(n_out):
        us = time_fn(
            lambda bn=bn: sspnna_fused(
                feats, weights, out_rows, in_rows, local_idx, counts,
                n_out=v, block_n=bn),
            iters=iters, warmup=1)
        if us < best_us:
            best_bn, best_us = bn, us
    _BLOCK_N_CACHE[key] = best_bn
    return best_bn
